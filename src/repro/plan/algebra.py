"""Logical relational algebra over crowdsourced entity joins (DESIGN.md §14).

Collections carry embeddings (the machine phase scores them), plain
machine-readable attribute columns (filters evaluate host-side for free),
and optionally ground-truth entity ids for simulated crowds.  Plans are
immutable trees; the optimizer (``plan/optimizer.py``) rewrites them and the
executor (``plan/executor.py``) compiles them to ``JoinService``
submissions.

Columns are qualified ``"collection.attr"`` names, so predicates are
attributable to one collection — the property filter pushdown keys on.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, FrozenSet, List, Optional, Tuple

import numpy as np


def row_fingerprints(embeddings: np.ndarray) -> List[str]:
    """Content fingerprint per row — the cross-query identity of an object
    (DESIGN.md §14).  Keyed on the embedding bytes, not the row position, so
    a grown or re-ordered collection still hits the cache for the rows it
    shares with an earlier query."""
    emb = np.ascontiguousarray(np.asarray(embeddings, np.float32))
    return [hashlib.blake2b(emb[i].tobytes(), digest_size=16).hexdigest()
            for i in range(emb.shape[0])]


def collection_fingerprint(fps: List[str]) -> str:
    """Order-insensitive digest over the row fingerprints."""
    h = hashlib.blake2b(digest_size=16)
    for fp in sorted(fps):
        h.update(bytes.fromhex(fp))
    return h.hexdigest()


@dataclasses.dataclass
class Collection:
    """A named table: (N, D) embeddings + machine-readable attr columns,
    optionally ground-truth ``entities`` for simulated crowds."""

    name: str
    embeddings: np.ndarray
    attrs: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    entities: Optional[np.ndarray] = None

    def __post_init__(self):
        self.embeddings = np.asarray(self.embeddings, np.float32)
        n = len(self.embeddings)
        self.attrs = {k: np.asarray(v) for k, v in self.attrs.items()}
        for k, v in self.attrs.items():
            if len(v) != n:
                raise ValueError(
                    f"attr {self.name}.{k} has {len(v)} values for "
                    f"{n} rows")
        if self.entities is not None:
            self.entities = np.asarray(self.entities)
            if len(self.entities) != n:
                raise ValueError(
                    f"entities of {self.name} has {len(self.entities)} "
                    f"values for {n} rows")
        self._fps: Optional[List[str]] = None

    def __len__(self) -> int:
        return len(self.embeddings)

    def fingerprints(self) -> List[str]:
        if self._fps is None:
            self._fps = row_fingerprints(self.embeddings)
        return self._fps

    def fingerprint(self) -> str:
        return collection_fingerprint(self.fingerprints())

    def columns(self) -> FrozenSet[str]:
        return frozenset(f"{self.name}.{k}" for k in self.attrs)

    def column(self, qualified: str) -> np.ndarray:
        coll, attr = qualified.split(".", 1)
        if coll != self.name or attr not in self.attrs:
            raise KeyError(qualified)
        return self.attrs[attr]


# -- predicates (machine-checkable, evaluated host-side) ---------------------

_OPS = {
    "==": np.equal, "!=": np.not_equal, "<": np.less, "<=": np.less_equal,
    ">": np.greater, ">=": np.greater_equal,
}


class Predicate:
    """Machine-checkable predicate over qualified columns.  ``mask`` takes a
    resolver ``col_name -> value array`` (all arrays same length) and returns
    a bool mask — usable both on a single collection's rows and on joined
    tuples."""

    def columns(self) -> FrozenSet[str]:
        raise NotImplementedError

    def mask(self, resolve) -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Cmp(Predicate):
    col: str
    op: str
    value: object

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(
                f"unknown comparison {self.op!r}; valid: {sorted(_OPS)}")

    def columns(self) -> FrozenSet[str]:
        return frozenset((self.col,))

    def mask(self, resolve) -> np.ndarray:
        return np.asarray(_OPS[self.op](resolve(self.col), self.value), bool)


@dataclasses.dataclass(frozen=True)
class IsIn(Predicate):
    col: str
    values: Tuple[object, ...]

    def columns(self) -> FrozenSet[str]:
        return frozenset((self.col,))

    def mask(self, resolve) -> np.ndarray:
        return np.isin(resolve(self.col), np.asarray(self.values))


@dataclasses.dataclass(frozen=True)
class And(Predicate):
    a: Predicate
    b: Predicate

    def columns(self) -> FrozenSet[str]:
        return self.a.columns() | self.b.columns()

    def mask(self, resolve) -> np.ndarray:
        return self.a.mask(resolve) & self.b.mask(resolve)


@dataclasses.dataclass(frozen=True)
class Or(Predicate):
    a: Predicate
    b: Predicate

    def columns(self) -> FrozenSet[str]:
        return self.a.columns() | self.b.columns()

    def mask(self, resolve) -> np.ndarray:
        return self.a.mask(resolve) | self.b.mask(resolve)


@dataclasses.dataclass(frozen=True)
class Not(Predicate):
    p: Predicate

    def columns(self) -> FrozenSet[str]:
        return self.p.columns()

    def mask(self, resolve) -> np.ndarray:
        return ~self.p.mask(resolve)


def conjuncts(p: Predicate) -> List[Predicate]:
    """Flatten a conjunction into its top-level terms (pushdown unit)."""
    if isinstance(p, And):
        return conjuncts(p.a) + conjuncts(p.b)
    return [p]


def conjoin(terms: List[Predicate]) -> Optional[Predicate]:
    if not terms:
        return None
    out = terms[0]
    for t in terms[1:]:
        out = And(out, t)
    return out


# -- plan nodes --------------------------------------------------------------


class Plan:
    def children(self) -> Tuple["Plan", ...]:
        raise NotImplementedError

    def columns(self) -> FrozenSet[str]:
        raise NotImplementedError

    def ordered_columns(self) -> Tuple[str, ...]:
        """Output column order of the LOGICAL plan (leaf order) — the
        executor materializes in this order regardless of how the optimizer
        reorders execution, so rewrites are tuple-for-tuple comparable."""
        out: List[str] = []
        for child in self.children():
            out.extend(c for c in child.ordered_columns() if c not in out)
        return tuple(out)

    def collections(self) -> Dict[str, Collection]:
        """Name -> collection, in leaf order.  Names must be unique — a
        self-join needs two differently-named Collection views."""
        out: Dict[str, Collection] = {}
        for child in self.children():
            for name, coll in child.collections().items():
                if name in out and out[name] is not coll:
                    raise ValueError(
                        f"collection name {name!r} appears twice in the "
                        "plan with different contents — alias one side")
                out[name] = coll
        return out

    def describe(self, indent: int = 0) -> str:
        pad = "  " * indent
        head = f"{pad}{type(self).__name__}{self._describe_args()}"
        kids = [c.describe(indent + 1) for c in self.children()]
        return "\n".join([head, *kids])

    def _describe_args(self) -> str:
        return ""


@dataclasses.dataclass
class Scan(Plan):
    collection: Collection

    def children(self) -> Tuple[Plan, ...]:
        return ()

    def columns(self) -> FrozenSet[str]:
        return self.collection.columns()

    def collections(self) -> Dict[str, Collection]:
        return {self.collection.name: self.collection}

    def ordered_columns(self) -> Tuple[str, ...]:
        return tuple(f"{self.collection.name}.{k}"
                     for k in self.collection.attrs)

    def _describe_args(self) -> str:
        return f"({self.collection.name}, {len(self.collection)} rows)"


@dataclasses.dataclass
class Filter(Plan):
    pred: Predicate
    child: Plan

    def __post_init__(self):
        missing = self.pred.columns() - self.child.columns()
        if missing:
            raise ValueError(
                f"filter references unknown columns {sorted(missing)}")

    def children(self) -> Tuple[Plan, ...]:
        return (self.child,)

    def columns(self) -> FrozenSet[str]:
        return self.child.columns()

    def _describe_args(self) -> str:
        return f"({self.pred})"


@dataclasses.dataclass
class Project(Plan):
    cols: Tuple[str, ...]
    child: Plan

    def __post_init__(self):
        self.cols = tuple(self.cols)
        missing = frozenset(self.cols) - self.child.columns()
        if missing:
            raise ValueError(
                f"project references unknown columns {sorted(missing)}")

    def children(self) -> Tuple[Plan, ...]:
        return (self.child,)

    def columns(self) -> FrozenSet[str]:
        return frozenset(self.cols)

    def ordered_columns(self) -> Tuple[str, ...]:
        return self.cols

    def _describe_args(self) -> str:
        return f"({', '.join(self.cols)})"


@dataclasses.dataclass
class CrowdJoin(Plan):
    """Binary crowdsourced entity join at a machine-phase cosine
    ``threshold``: candidate pairs above it are resolved by the crowd (plus
    transitive deduction); output tuples pair rows of one resolved entity."""

    left: Plan
    right: Plan
    threshold: float

    def children(self) -> Tuple[Plan, ...]:
        return (self.left, self.right)

    def columns(self) -> FrozenSet[str]:
        return self.left.columns() | self.right.columns()

    def _describe_args(self) -> str:
        return f"(threshold={self.threshold})"


@dataclasses.dataclass
class MultiJoin(Plan):
    """N-way crowdsourced join over one shared entity universe: every
    cross-collection pair above ``threshold`` is a candidate, tuples take
    one row per collection from each resolved entity cluster.  The input
    order is the execution order — the optimizer reorders it by expected
    crowd cost (DESIGN.md §14)."""

    inputs: List[Plan]
    threshold: float

    def __post_init__(self):
        if len(self.inputs) < 2:
            raise ValueError("MultiJoin needs at least two inputs")

    def children(self) -> Tuple[Plan, ...]:
        return tuple(self.inputs)

    def columns(self) -> FrozenSet[str]:
        out: FrozenSet[str] = frozenset()
        for child in self.inputs:
            out = out | child.columns()
        return out

    def _describe_args(self) -> str:
        return f"(threshold={self.threshold}, {len(self.inputs)} legs)"


def leg(plan: Plan) -> Optional[Tuple[Collection, np.ndarray]]:
    """Resolve a join leg — a Filter*/Scan chain — to (collection, row mask).
    Returns None when the subtree contains a join or projection (not a
    leg)."""
    if isinstance(plan, Scan):
        return plan.collection, np.ones(len(plan.collection), bool)
    if isinstance(plan, Filter):
        below = leg(plan.child)
        if below is None:
            return None
        coll, mask = below
        return coll, mask & plan.pred.mask(coll.column)
    return None
