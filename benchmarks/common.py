"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time
from contextlib import contextmanager

from repro.data.entities import make_paper_dataset, make_product_dataset

_CACHE = {}


def dataset(name: str):
    if name not in _CACHE:
        _CACHE[name] = (make_paper_dataset() if name == "paper"
                        else make_product_dataset())
    return _CACHE[name]


def row(name: str, us: float, derived: str) -> str:
    """CSV row in the harness format: name,us_per_call,derived."""
    return f"{name},{us:.1f},{derived}"


@contextmanager
def timed():
    t = {}
    t0 = time.perf_counter()
    yield t
    t["us"] = (time.perf_counter() - t0) * 1e6
