"""Adaptive labeling order (DESIGN.md §10) — posterior-refreshed priorities.

The paper's practical heuristic (§4.2) sorts candidate pairs by machine
likelihood **once** and never revisits that order, yet every crowd answer
changes the expected-deduction value of the remaining pairs.  *The Expected
Optimal Labeling Order Problem for Crowdsourced Joins and Entity Resolution*
(Wang et al., 2014) formalizes the gap: orders that track the live cluster
structure dominate static likelihood sorting, because labeling a pair that
merges two large components deduces every cross pair between them for free
(the component-growth argument behind Theorem 1's matching-first optimality).

This module turns :class:`~repro.core.jax_graph.SessionState.priority` into
that live quantity.  Per pending pair ``(u, v)`` with machine prior ``p``:

* ``du``/``dv`` — live negative degrees of the two clusters: the number of
  *distinct* clusters each is negatively adjacent to, counted from the
  union-find ``roots`` and the sorted ``neg_keys`` index (duplicate keys —
  deduced NEGs — count once, so the host oracle's ``ClusterGraph.neg``
  sets agree exactly);
* **posterior / gain** ``p / (1 + NEG_DAMP * (du + dv))`` — the prior
  damped by the accumulated negative evidence around the pair's clusters:
  a cluster the crowd keeps separating from its neighbours is a
  well-delineated entity, so an unlabeled edge into it is less likely to
  match than the machine score alone suggests.

Ranking by this posterior is the component-growth argument in heuristic
form: Theorem 1 says *matching pairs first* is optimal (each match grows a
component, compounding future deductions), and the §4.2 likelihood sort is
its deployable surrogate; the live posterior is a strictly better match-
probability estimate than the frozen prior, so ranking on it moves the
order closer to true matching-first as evidence accumulates.  Explicit
structure bonuses were measured and *hurt*: boosting by cluster size or by
cluster-pair candidate multiplicity promotes probable non-matches ahead of
probable matches, which breaks exactly the property Theorem 1 needs
(on the Cora-like benchmark: posterior 1571 crowdsourced pairs vs 1611
static expected vs 1523 ground-truth optimal; size/multiplicity variants
1738-2518).

``priority = -gain`` (the frontier selects minimum priority), refreshed only
on *pending* pairs (UNKNOWN and not in flight): published and labeled pairs
keep their old priority, and since the frontier never selects either, a
refresh can never revive them (property-tested).  The formula is pure f32
mul/add/div — no transcendentals — so the device (XLA) and host (NumPy)
paths produce bit-identical scores and therefore identical rankings.

With no negative evidence yet (round 1) the gain reduces to the clipped
prior, so adaptive ordering starts as the §4.2 likelihood-descending
heuristic and diverges only once structure accumulates.

The ordering also steers mixed scheduling (DESIGN.md §15): the cluster-task
planner grows its multi-pair tasks around the objects of the
frontier-selected pairs and values a candidate task only by the *frontier*
pairs it covers — harvested off-frontier pairs ride along at zero credited
value, since deduction would have labeled most of them for free.  A better
frontier therefore concentrates cluster tasks where the next round's
information actually is.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .cluster_graph import ClusterGraph, UNKNOWN
from .jax_graph import SessionState, _decompose_keys, engine_dispatches

# Damping per unit of negative degree around the pair's clusters.  0.25 is a
# power of two, so `1 + NEG_DAMP * k` is exact in f32 and the host/device
# score parity stays bitwise.
NEG_DAMP = 0.25

# Priors are clipped away from {0, 1}: a 0-likelihood pair still in the
# candidate set must keep a total order under the stable rank tie-break.
PRIOR_FLOOR = 1e-4


# ---------------------------------------------------------------------------
# Device path (jit / vmap over SessionState)
# ---------------------------------------------------------------------------
def _neg_degree_impl(state: SessionState) -> jax.Array:
    """Distinct negative degree per root, f32 (n,)."""
    n = state.n_objects
    lo, hi, is_pad = _decompose_keys(state.neg_keys, n)
    # neg_keys is sorted, so duplicates (deduced NEGs) are adjacent: count
    # each distinct cluster-pair key once, matching ClusterGraph.neg sets
    first = jnp.concatenate([
        jnp.ones((1,), bool),
        state.neg_keys[1:] != state.neg_keys[:-1]])
    w = jnp.where(is_pad | ~first, 0.0, 1.0).astype(jnp.float32)
    return jnp.zeros((n,), jnp.float32).at[lo].add(w).at[hi].add(w)


def _gains_impl(state: SessionState, prior: jax.Array) -> jax.Array:
    """Posterior match probability / expected-deduction gain per pair
    (f32 (P,)); meaningful on pending pairs, computed everywhere (callers
    mask)."""
    negdeg = _neg_degree_impl(state)
    ru, rv = state.roots[state.u], state.roots[state.v]
    p = jnp.clip(prior.astype(jnp.float32), PRIOR_FLOOR, 1.0 - PRIOR_FLOOR)
    damp = 1.0 + NEG_DAMP * (negdeg[ru] + negdeg[rv])
    return p / damp


def _refresh_impl(state: SessionState, prior: jax.Array) -> SessionState:
    """Fold refreshed priorities into the state: pending pairs get
    ``-gain`` (highest gain labels first), published/labeled pairs keep
    their old priority — they are out of the frontier's reach either way,
    so a refresh can never revive them."""
    gain = _gains_impl(state, prior)
    pending = (state.labels == UNKNOWN) & ~state.published
    prio = jnp.where(pending, -gain, state.priority)
    return dataclasses.replace(state, priority=prio)


def _refresh_masked_impl(state: SessionState, prior: jax.Array,
                         enable: jax.Array) -> SessionState:
    """Batched helper: refresh only where the per-session ``enable`` scalar
    holds (lanes serving a static order keep positional priorities)."""
    refreshed = _refresh_impl(state, prior)
    prio = jnp.where(enable, refreshed.priority, state.priority)
    return dataclasses.replace(state, priority=prio)


_session_gains_jit = jax.jit(_gains_impl)
_session_gains_batch_jit = jax.jit(jax.vmap(_gains_impl))
# refresh is state-in/state-out: donate the state so the priority write is
# in place and the untouched fields alias straight through (DESIGN.md §13)
_session_refresh_jit = jax.jit(_refresh_impl, donate_argnums=(0,))
_session_refresh_batch_jit = jax.jit(jax.vmap(_refresh_masked_impl),
                                     donate_argnums=(0,))


def session_gains(state: SessionState, prior) -> jax.Array:
    """(P,) f32 expected-deduction gains from the live state (one dispatch).
    The budget scheduler ranks crowd slots across sessions on these."""
    engine_dispatches.add()
    return _session_gains_jit(state, prior)


def session_gains_batch(state: SessionState, prior) -> jax.Array:
    """(B, P) stacked gains, one dispatch for B sessions."""
    engine_dispatches.add()
    return _session_gains_batch_jit(state, prior)


def session_refresh_priorities(state: SessionState, prior) -> SessionState:
    """Refresh pending-pair priorities from the live posterior (DESIGN.md
    §10); published/labeled pairs are untouched.  One dispatch."""
    engine_dispatches.add()
    return _session_refresh_jit(state, prior)


def session_refresh_priorities_batch(state: SessionState, prior,
                                     enable) -> SessionState:
    """Batched refresh over stacked states; ``enable`` is a (B,) bool mask
    of sessions whose order is adaptive (the rest keep their priorities)."""
    engine_dispatches.add()
    return _session_refresh_batch_jit(state, prior, jnp.asarray(enable))


# ---------------------------------------------------------------------------
# Host oracle (ClusterGraph): the same scores from the pointer-chasing graph
# ---------------------------------------------------------------------------
def adaptive_gains_host(graph: ClusterGraph, u: np.ndarray, v: np.ndarray,
                        likelihood: np.ndarray) -> np.ndarray:
    """Expected-deduction gains from a live :class:`ClusterGraph` — the host
    mirror of :func:`session_gains`, op-for-op in f32 so rankings agree with
    the device path bit-for-bit.  O(n + P) per call: roots materialize once,
    the per-pair math is vectorized."""
    n = len(graph.parent)
    roots_all = np.fromiter((graph.find(i) for i in range(n)), np.int64, n)
    negdeg = np.zeros(n, np.float32)
    for r, enemies in graph.neg.items():
        negdeg[r] = len(enemies)  # keys are live roots (maintained on union)
    ru = roots_all[np.asarray(u, np.int64)]
    rv = roots_all[np.asarray(v, np.int64)]
    p = np.clip(np.asarray(likelihood, np.float32),
                np.float32(PRIOR_FLOOR), np.float32(1.0 - PRIOR_FLOOR))
    damp = np.float32(1.0) + np.float32(NEG_DAMP) * (negdeg[ru] + negdeg[rv])
    return p / damp


def expected_rank(likelihood: np.ndarray) -> np.ndarray:
    """Each pair's position in the static expected (likelihood-descending)
    order — the tie-break key of the adaptive ranking, mirroring the
    engine's stable rank tie-break over pairs stored in expected order."""
    n = len(likelihood)
    rank = np.empty(n, np.int64)
    rank[np.argsort(-np.asarray(likelihood), kind="stable")] = np.arange(n)
    return rank


def adaptive_order_host(gains: np.ndarray, erank: np.ndarray,
                        idx: np.ndarray) -> np.ndarray:
    """Order the pair indices ``idx`` by descending live gain, ties broken
    by the static expected rank — the one ranking both host adaptive
    labelers share (keeping them in lockstep with each other and with the
    engine's tie-break)."""
    return idx[np.lexsort((erank[idx], -gains[idx]))]
