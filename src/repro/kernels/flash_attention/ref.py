"""Pure-jnp oracle: causal multi-head attention (GQA via head repetition)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mha_causal_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """q: (B, S, H, d); k, v: (B, S, K, d) with H % K == 0."""
    B, S, H, d = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, d).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(d)
    mask = jnp.tril(jnp.ones((S, S), dtype=bool))
    s = jnp.where(mask, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(B, S, H, d).astype(q.dtype)
