"""Model assembly for all architecture families.

Parameters are a nested dict pytree; per-layer params carry a leading
``layers`` axis and the backbone runs under ``lax.scan`` (bounds HLO size at
95 layers) with optional per-block remat.  A single spec table per config is
the source of truth for shapes, logical sharding axes and init; the dry-run
gets abstract params via ``jax.eval_shape(init_params, ...)`` (no allocation).

Entry points
  init_params / param_axes                — params + logical axes pytrees
  loss_fn(params, batch, cfg)             — next-token CE train loss
  prefill(params, batch, cfg)             — inference prefill -> (cache, logits)
  decode_step(params, cache, batch, cfg)  — one-token decode with cache
  layer_step / decode_layer_step          — single-layer fns for the dry-run
                                            FLOP accounting (inner loops can
                                            be unrolled; see launch/dryrun.py)
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (ParamSpec, Specs, _position_encode, _qkv,
                     attention_block, attention_decode_block, attention_specs,
                     chunked_causal_attention, mlp_block, mlp_specs, rmsnorm,
                     rmsnorm_specs)
from .moe import moe_block, moe_specs
from .ssm import (mamba2_block, mamba2_decode_step, mamba2_specs,
                  rwkv6_channel_mix, rwkv6_specs, rwkv6_time_mix)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Spec tables
# ---------------------------------------------------------------------------
def _prefix(prefix: str, specs: Specs) -> Specs:
    return {f"{prefix}/{k}": v for k, v in specs.items()}


def layer_specs(cfg: ModelConfig) -> Specs:
    """Specs for ONE layer (no leading layers axis)."""
    s: Specs = {}
    if cfg.rwkv:
        s.update(_prefix("ln1", rmsnorm_specs(cfg.d_model)))
        s.update(_prefix("ln2", rmsnorm_specs(cfg.d_model)))
        s.update(rwkv6_specs(cfg))
        return s
    if cfg.family == "hybrid":
        s.update(_prefix("ln1", rmsnorm_specs(cfg.d_model)))
        s.update(_prefix("mamba", mamba2_specs(cfg)))
        return s
    # attention families
    s.update(_prefix("ln1", rmsnorm_specs(cfg.d_model)))
    s.update(_prefix("ln2", rmsnorm_specs(cfg.d_model)))
    s.update(_prefix("attn", attention_specs(cfg)))
    if cfg.is_moe:
        s.update(_prefix("moe", moe_specs(cfg)))
    else:
        s.update(_prefix("mlp", mlp_specs(cfg)))
    return s


def shared_attn_specs(cfg: ModelConfig) -> Specs:
    """zamba2 shared attention(+MLP) block over concat(hidden, embedding)."""
    s: Specs = {}
    s.update(_prefix("ln_in", rmsnorm_specs(2 * cfg.d_model)))
    s.update(_prefix("attn", attention_specs(cfg, d_in=2 * cfg.d_model)))
    s.update(_prefix("ln_mlp", rmsnorm_specs(cfg.d_model)))
    s.update(_prefix("mlp", mlp_specs(cfg)))
    return s


def model_specs(cfg: ModelConfig) -> Specs:
    s: Specs = {
        "embed/table": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                                 fan_in=cfg.d_model),
        "final_norm/scale": ParamSpec((cfg.d_model,), (None,), fan_in=0),
    }
    if not cfg.tie_embeddings:
        s["lm_head/w"] = ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"),
                                   fan_in=cfg.d_model)
    for k, v in layer_specs(cfg).items():
        s[f"layers/{k}"] = ParamSpec((cfg.n_layers,) + v.shape,
                                     ("layers",) + v.axes, v.fan_in, v.dtype)
    if cfg.attn_every:
        for k, v in shared_attn_specs(cfg).items():
            s[f"shared/{k}"] = v
    return s


def _nest(flat: Dict[str, Any]) -> Params:
    out: Params = {}
    for path, v in flat.items():
        node = out
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return out


def _special_init(path: str, spec: ParamSpec, key) -> Optional[jax.Array]:
    leaf = path.split("/")[-1]
    if leaf == "A_log":
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(spec.dtype)
    if leaf == "dt_bias":
        dt = jax.random.uniform(key, spec.shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(dt)).astype(spec.dtype)
    if leaf == "D":
        return jnp.ones(spec.shape, spec.dtype)
    if leaf == "w0":
        return jnp.full(spec.shape, -5.0, spec.dtype)
    if leaf.startswith("mu_"):
        return jnp.full(spec.shape, 0.5, spec.dtype)
    if leaf == "bonus_u":
        return (jax.random.normal(key, spec.shape, jnp.float32) * 0.1).astype(spec.dtype)
    return None


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    specs = model_specs(cfg)
    keys = jax.random.split(key, len(specs))
    flat = {}
    for (path, spec), k in zip(sorted(specs.items()), keys):
        sp = _special_init(path, spec, k)
        if sp is not None:
            flat[path] = sp
        elif spec.fan_in == 0:
            flat[path] = jnp.zeros(spec.shape, spec.dtype)
        else:
            scale = 1.0 / math.sqrt(max(spec.fan_in, 1))
            flat[path] = (jax.random.normal(k, spec.shape, jnp.float32) * scale
                          ).astype(spec.dtype)
    return _nest(flat)


def param_axes(cfg: ModelConfig) -> Params:
    return _nest({p: s.axes for p, s in model_specs(cfg).items()})


def abstract_params(cfg: ModelConfig) -> Params:
    return _nest({
        p: jax.ShapeDtypeStruct(s.shape, s.dtype)
        for p, s in model_specs(cfg).items()
    })


def n_params(cfg: ModelConfig) -> int:
    return sum(math.prod(s.shape) for s in model_specs(cfg).values())


def n_active_params(cfg: ModelConfig) -> int:
    """Per-token active params (MoE: top_k of n_experts)."""
    total = 0
    for p, s in model_specs(cfg).items():
        sz = math.prod(s.shape)
        if "/moe/w" in p:
            sz = sz * cfg.top_k // cfg.n_experts
        total += sz
    return total


# ---------------------------------------------------------------------------
# Layer application (shared by train forward / prefill / accounting)
# ---------------------------------------------------------------------------
def layer_step(lp: Params, x: jax.Array, positions: jax.Array,
               layer_idx: jax.Array, cfg: ModelConfig,
               shared: Optional[Params] = None,
               x_embed: Optional[jax.Array] = None,
               unroll: bool = False) -> Tuple[jax.Array, jax.Array]:
    """One backbone layer. Returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    if cfg.rwkv:
        B = x.shape[0]
        zero = jnp.zeros((B, 1, cfg.d_model), x.dtype)
        h, _, _ = rwkv6_time_mix(rmsnorm(x, lp["ln1"]["scale"], cfg.norm_eps),
                                 zero, lp, cfg, unroll=unroll)
        x = x + h
        h, _ = rwkv6_channel_mix(rmsnorm(x, lp["ln2"]["scale"], cfg.norm_eps),
                                 zero, lp, cfg)
        x = x + h
        return x, aux
    if cfg.family == "hybrid":
        h = rmsnorm(x, lp["ln1"]["scale"], cfg.norm_eps)
        x = x + mamba2_block(h, lp["mamba"], cfg, unroll=unroll)
        if cfg.attn_every and shared is not None:
            def apply_shared(xx):
                cat = jnp.concatenate([xx, x_embed], axis=-1)
                h2 = rmsnorm(cat, shared["ln_in"]["scale"], cfg.norm_eps)
                a = attention_block(h2, shared["attn"], cfg, positions,
                                    unroll=unroll)
                xx = xx + a
                h3 = rmsnorm(xx, shared["ln_mlp"]["scale"], cfg.norm_eps)
                return xx + mlp_block(h3, shared["mlp"], cfg)
            x = jax.lax.cond(layer_idx % cfg.attn_every == 0, apply_shared,
                             lambda xx: xx, x)
        return x, aux
    # attention families
    h = rmsnorm(x, lp["ln1"]["scale"], cfg.norm_eps)
    x = x + attention_block(h, lp["attn"], cfg, positions, unroll=unroll)
    h = rmsnorm(x, lp["ln2"]["scale"], cfg.norm_eps)
    if cfg.is_moe:
        if cfg.moe_impl == "a2a":
            from repro.sharding import _CURRENT
            from .moe_a2a import moe_block_a2a
            m, aux = moe_block_a2a(h, lp["moe"], cfg, _CURRENT["mesh"])
        else:
            m, aux = moe_block(h, lp["moe"], cfg)
    else:
        m = mlp_block(h, lp["mlp"], cfg)
    x = x + m
    return x, aux


def _embed_inputs(params: Params, batch: Dict[str, jax.Array],
                  cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Returns (x (B,S,d), positions)."""
    tokens = batch["tokens"]
    x = params["embed"]["table"][tokens]
    prefix = batch.get("prefix_embeds")     # vlm patches / audio frames (stub)
    if prefix is not None:
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    B, S = x.shape[:2]
    if cfg.mrope:
        positions = batch["positions3"]     # (B,S,3) from the vision stub
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return x, positions


def backbone(params: Params, x: jax.Array, positions: jax.Array,
             cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Scan over layers. Returns (hidden, total aux loss)."""
    shared = params.get("shared")
    x_embed = x if cfg.attn_every else None

    def body(carry, inp):
        xx, aux_sum = carry
        lp, li = inp
        fn = lambda q: layer_step(lp, q, positions, li, cfg, shared, x_embed)
        if cfg.remat == "block":
            fn = jax.checkpoint(fn)
        xx, aux = fn(xx)
        return (xx, aux_sum + aux), None

    lidx = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                               (params["layers"], lidx))
    return x, aux


def _logits(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    head = (params["embed"]["table"].T if cfg.tie_embeddings
            else params["lm_head"]["w"])
    logits = x @ head
    return logits.astype(jnp.float32) if cfg.logits_f32 else logits


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig
            ) -> jax.Array:
    """Next-token cross-entropy; positions with target < 0 are masked."""
    x, positions = _embed_inputs(params, batch, cfg)
    x, aux = backbone(params, x, positions, cfg)
    logits = _logits(params, x, cfg)
    targets = batch["targets"]               # (B, S_total) aligned with x
    mask = (targets >= 0).astype(jnp.float32)
    t = jnp.clip(targets, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# Serving: caches, prefill, decode
# ---------------------------------------------------------------------------
def make_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """Abstract-shape-compatible cache pytree (zeros)."""
    L = cfg.n_layers
    c: Params = {"length": jnp.zeros((), jnp.int32)}
    if cfg.rwkv:
        H = cfg.d_model // cfg.ssm_head_dim
        hd = cfg.ssm_head_dim
        c["wkv"] = jnp.zeros((L, batch, H, hd, hd), jnp.float32)
        c["tm_x"] = jnp.zeros((L, batch, 1, cfg.d_model), jnp.bfloat16)
        c["cm_x"] = jnp.zeros((L, batch, 1, cfg.d_model), jnp.bfloat16)
        return c
    if cfg.family == "hybrid":
        H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        c["ssm"] = jnp.zeros((L, batch, H, N, P), jnp.float32)
        c["conv"] = jnp.zeros((L, batch, cfg.ssm_conv - 1, conv_dim), jnp.bfloat16)
        ns = cfg.n_shared_attn
        c["k"] = jnp.zeros((ns, batch, max_len, cfg.n_kv_heads, cfg.hd), jnp.bfloat16)
        c["v"] = jnp.zeros((ns, batch, max_len, cfg.n_kv_heads, cfg.hd), jnp.bfloat16)
        return c
    kv_dt = jnp.int8 if cfg.kv_quant else jnp.bfloat16
    c["k"] = jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.hd), kv_dt)
    c["v"] = jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.hd), kv_dt)
    if cfg.kv_quant:
        c["k_scale"] = jnp.zeros((L, batch, max_len, cfg.n_kv_heads), jnp.bfloat16)
        c["v_scale"] = jnp.zeros((L, batch, max_len, cfg.n_kv_heads), jnp.bfloat16)
    return c


def cache_axes(cfg: ModelConfig) -> Params:
    """Logical axes for cache sharding (batch over data, heads over model)."""
    ax: Params = {"length": ()}
    if cfg.rwkv:
        ax["wkv"] = (None, "batch", "ssm_heads", None, None)
        ax["tm_x"] = (None, "batch", None, None)
        ax["cm_x"] = (None, "batch", None, None)
        return ax
    if cfg.family == "hybrid":
        ax["ssm"] = (None, "batch", "ssm_heads", None, None)
        ax["conv"] = (None, "batch", None, None)
        ax["k"] = (None, "batch", "kv_seq", "kv_cache_heads", None)
        ax["v"] = (None, "batch", "kv_seq", "kv_cache_heads", None)
        return ax
    ax["k"] = (None, "batch", "kv_seq", "kv_cache_heads", None)
    ax["v"] = (None, "batch", "kv_seq", "kv_cache_heads", None)
    if cfg.kv_quant:
        ax["k_scale"] = (None, "batch", "kv_seq", "kv_cache_heads")
        ax["v_scale"] = (None, "batch", "kv_seq", "kv_cache_heads")
    return ax


def decode_layer_step(lp: Params, x: jax.Array, cfg: ModelConfig,
                      layer_cache: Dict[str, jax.Array], length: jax.Array,
                      positions: jax.Array, layer_idx: jax.Array):
    """One layer of single-token decode (non-hybrid families).
    Returns (x, new_layer_cache)."""
    new_cache = dict(layer_cache)
    if cfg.rwkv:
        h = rmsnorm(x, lp["ln1"]["scale"], cfg.norm_eps)
        h, wkv, tm_x = rwkv6_time_mix(h, layer_cache["tm_x"], lp, cfg,
                                      state0=layer_cache["wkv"])
        x = x + h
        h = rmsnorm(x, lp["ln2"]["scale"], cfg.norm_eps)
        h, cm_x = rwkv6_channel_mix(h, layer_cache["cm_x"], lp, cfg)
        x = x + h
        new_cache.update(wkv=wkv, tm_x=tm_x, cm_x=cm_x)
        return x, new_cache
    h = rmsnorm(x, lp["ln1"]["scale"], cfg.norm_eps)
    if cfg.kv_quant:
        a, kc, vc, ks, vs = attention_decode_block(
            h, lp["attn"], cfg, positions, layer_cache["k"], layer_cache["v"],
            length, layer_cache["k_scale"], layer_cache["v_scale"])
        new_cache.update(k_scale=ks, v_scale=vs)
    else:
        a, kc, vc = attention_decode_block(h, lp["attn"], cfg, positions,
                                           layer_cache["k"], layer_cache["v"],
                                           length)
    x = x + a
    h = rmsnorm(x, lp["ln2"]["scale"], cfg.norm_eps)
    if cfg.is_moe:
        m, _ = moe_block(h, lp["moe"], cfg)
    else:
        m = mlp_block(h, lp["mlp"], cfg)
    x = x + m
    return x, new_cache


def decode_step(params: Params, cache: Params, batch: Dict[str, jax.Array],
                cfg: ModelConfig) -> Tuple[jax.Array, Params]:
    """One new token for every sequence in the batch.
    batch: {"tokens": (B,1) int32}. Returns (logits (B,1,V), new cache)."""
    tokens = batch["tokens"]
    x = params["embed"]["table"][tokens]           # (B,1,d)
    B = x.shape[0]
    length = cache["length"]
    if cfg.mrope:
        # the serving layer tracks the M-RoPE position streams
        positions = batch.get("positions3")
        if positions is None:
            positions = jnp.broadcast_to(length, (B, 1, 3)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(length, (B, 1)).astype(jnp.int32)
    shared = params.get("shared")
    x_embed = x if cfg.attn_every else None

    if cfg.family == "hybrid":
        # python loop over layers: avoids scan-materializing L copies of the
        # (n_shared)-indexed shared KV caches (decode ops are tiny anyway)
        new_cache = dict(cache)
        ssm_new, conv_new = [], []
        k_all, v_all = cache["k"], cache["v"]
        for li in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[li], params["layers"])
            h = rmsnorm(x, lp["ln1"]["scale"], cfg.norm_eps)
            h, ssm, conv = mamba2_decode_step(h, lp["mamba"], cfg,
                                              cache["ssm"][li], cache["conv"][li])
            x = x + h
            ssm_new.append(ssm)
            conv_new.append(conv)
            if cfg.attn_every and li % cfg.attn_every == 0:
                inv = li // cfg.attn_every
                cat = jnp.concatenate([x, x_embed], axis=-1)
                h2 = rmsnorm(cat, shared["ln_in"]["scale"], cfg.norm_eps)
                a, kc, vc = attention_decode_block(
                    h2, shared["attn"], cfg, positions, k_all[inv], v_all[inv],
                    length)
                x = x + a
                h3 = rmsnorm(x, shared["ln_mlp"]["scale"], cfg.norm_eps)
                x = x + mlp_block(h3, shared["mlp"], cfg)
                k_all = k_all.at[inv].set(kc)
                v_all = v_all.at[inv].set(vc)
        new_cache.update(
            ssm=jnp.stack(ssm_new), conv=jnp.stack(conv_new),
            k=k_all, v=v_all, length=length + 1)
        logits = _logits(params, x, cfg)
        return logits, new_cache

    # per-layer cache slices become scan xs; updated slices are scan ys
    layer_keys = [k for k in cache.keys() if k != "length"]

    def body(carry, inp):
        xx = carry
        lp, lc, li = inp
        xx, nc = decode_layer_step(lp, xx, cfg, lc, length, positions, li)
        return xx, nc

    lidx = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    per_layer_cache = {k: cache[k] for k in layer_keys}
    xs = (params["layers"], per_layer_cache, lidx)
    x, new_caches = jax.lax.scan(body, x, xs)

    new_cache = dict(cache)
    for k in layer_keys:
        new_cache[k] = new_caches[k]
    new_cache["length"] = length + 1
    logits = _logits(params, x, cfg)
    return logits, new_cache


def prefill(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            max_len: int) -> Tuple[Params, jax.Array]:
    """Inference prefill: full forward building the KV cache; returns
    (cache, last-position logits).  For SSM/hybrid archs the recurrent states
    come out of the scan-form blocks."""
    x, positions = _embed_inputs(params, batch, cfg)
    B, S = x.shape[:2]
    cache = make_cache(cfg, B, max_len)
    cache["length"] = jnp.asarray(S, jnp.int32)

    if cfg.rwkv:
        def body(carry, lp):
            xx = carry
            h = rmsnorm(xx, lp["ln1"]["scale"], cfg.norm_eps)
            zero = jnp.zeros((B, 1, cfg.d_model), xx.dtype)
            h, wkv, tm_x = rwkv6_time_mix(h, zero, lp, cfg)
            xx = xx + h
            h2 = rmsnorm(xx, lp["ln2"]["scale"], cfg.norm_eps)
            h2, cm_x = rwkv6_channel_mix(h2, zero, lp, cfg)
            xx = xx + h2
            return xx, {"wkv": wkv, "tm_x": tm_x.astype(jnp.bfloat16),
                        "cm_x": cm_x.astype(jnp.bfloat16)}

        x_out, states = jax.lax.scan(body, x, params["layers"])
        cache.update(states)
        logits = _logits(params, x_out[:, -1:], cfg)
        return cache, logits

    if cfg.family == "hybrid":
        # scan over layers (bounds HLO like the train backbone); the shared
        # attention block runs under lax.cond, emitting its fresh K/V when it
        # fires and zeros otherwise — the per-invocation caches are gathered
        # from the emitted stack afterwards.
        shared = params.get("shared")
        x_embed = x
        K, hd = cfg.n_kv_heads, cfg.hd

        def body(carry, inp):
            xx = carry
            lp, li = inp
            h = rmsnorm(xx, lp["ln1"]["scale"], cfg.norm_eps)
            out, (ssm, conv) = mamba2_block(h, lp["mamba"], cfg,
                                            return_state=True)
            xx = xx + out

            def apply_shared(xx):
                cat = jnp.concatenate([xx, x_embed], axis=-1)
                h2 = rmsnorm(cat, shared["ln_in"]["scale"], cfg.norm_eps)
                q, k, v = _qkv(h2, shared["attn"], cfg)
                q, k = _position_encode(q, k, positions, cfg)
                if cfg.attn_impl == "naive":
                    from .layers import naive_causal_attention
                    o = naive_causal_attention(q, k, v, cfg)
                else:
                    o = chunked_causal_attention(q, k, v, cfg)
                xx = xx + o.reshape(B, S, -1) @ shared["attn"]["wo"]
                h3 = rmsnorm(xx, shared["ln_mlp"]["scale"], cfg.norm_eps)
                xx = xx + mlp_block(h3, shared["mlp"], cfg)
                return xx, k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)

            def skip(xx):
                z = jnp.zeros((B, S, K, hd), jnp.bfloat16)
                return xx, z, z

            xx, k, v = jax.lax.cond(li % cfg.attn_every == 0, apply_shared,
                                    skip, xx)
            return xx, {"ssm": ssm, "conv": conv.astype(jnp.bfloat16),
                        "k": k, "v": v}

        lidx = jnp.arange(cfg.n_layers, dtype=jnp.int32)
        x_out, states = jax.lax.scan(body, x, (params["layers"], lidx))
        cache["ssm"] = states["ssm"]
        cache["conv"] = states["conv"]
        inv_idx = jnp.arange(cfg.n_shared_attn) * cfg.attn_every
        pad = ((0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0))
        cache["k"] = jnp.pad(states["k"][inv_idx], pad)
        cache["v"] = jnp.pad(states["v"][inv_idx], pad)
        logits = _logits(params, x_out[:, -1:], cfg)
        return cache, logits

    # attention families: forward while stashing K/V per layer
    def body(carry, lp):
        xx = carry
        h = rmsnorm(xx, lp["ln1"]["scale"], cfg.norm_eps)
        q, k, v = _qkv(h, lp["attn"], cfg)
        q, k = _position_encode(q, k, positions, cfg)
        if cfg.attn_impl == "naive":
            from .layers import naive_causal_attention
            o = naive_causal_attention(q, k, v, cfg)
        else:
            o = chunked_causal_attention(q, k, v, cfg)
        xx = xx + o.reshape(B, S, -1) @ lp["attn"]["wo"]
        h = rmsnorm(xx, lp["ln2"]["scale"], cfg.norm_eps)
        if cfg.is_moe:
            m, _ = moe_block(h, lp["moe"], cfg)
        else:
            m = mlp_block(h, lp["mlp"], cfg)
        xx = xx + m
        return xx, {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}

    x_out, kv = jax.lax.scan(body, x, params["layers"])
    pad5 = ((0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0))
    if cfg.kv_quant:
        from .layers import quantize_kv
        kq, ks = quantize_kv(kv["k"])
        vq, vs = quantize_kv(kv["v"])
        cache["k"] = jnp.pad(kq, pad5)
        cache["v"] = jnp.pad(vq, pad5)
        pad4 = ((0, 0), (0, 0), (0, max_len - S), (0, 0))
        cache["k_scale"] = jnp.pad(ks, pad4)
        cache["v_scale"] = jnp.pad(vs, pad4)
    else:
        cache["k"] = jnp.pad(kv["k"], pad5)
        cache["v"] = jnp.pad(kv["v"], pad5)
    logits = _logits(params, x_out[:, -1:], cfg)
    return cache, logits
