import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first initialization, and the production meshes below need 256/512
# placeholder host devices.  (Only the dry-run sets this — tests/benches see
# the real single device.)

"""Multi-pod dry-run (deliverable e) + roofline raw-term extraction (g).

For every (architecture x input-shape x mesh) cell this lowers + compiles the
real step function under the production mesh, proving the distribution config
is coherent:

  train_4k    -> train_step  (fwd+bwd+AdamW update, donated params/opt)
  prefill_32k -> prefill     (cache build + last logits)
  decode_32k  -> serve_step  (one token over a 32k KV cache, donated cache)
  long_500k   -> serve_step  (SSM/hybrid archs only; see DESIGN.md)

and records memory_analysis() + cost_analysis() + a collective-bytes parse of
the partitioned HLO into a JSON artifact per cell.

FLOP-accounting correction (EXPERIMENTS.md §Roofline): XLA's HloCostAnalysis
counts a while-loop body ONCE, so the scanned-over-layers full-model numbers
undercount by ~n_layers.  Each cell therefore ALSO lowers the per-layer step
(inner chunk loops unrolled) and the embed/head "outer" step separately, and
reports   total = outer + n_layers * layer   (RWKV's time scan is unrolled at
a reduced S and scaled linearly — every RWKV6 op is linear in S).
"""
import argparse
import json
import math
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get
from repro.configs.shapes import SHAPES, input_specs, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.sharding import (RULE_SETS, batch_sharding, replicated,
                            set_current_mesh, sharding_tree, spec_for)
from repro.train.optim import AdamWConfig, abstract_opt_state, adamw_update, opt_state_axes

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------
_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1}
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")
_COLL_RE = re.compile(r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
                      r"collective-permute)(-start)?\(")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> dict:
    """Approximate bytes moved per device per collective op (result-shape
    based; all-reduce counted 2x = reduce-scatter + all-gather of a ring)."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for line in hlo_text.splitlines():
        mm = _COLL_RE.search(line)
        if not mm or "-done" in line:
            continue
        kind = mm.group(1)
        eq = line.find(" = ")
        if eq < 0:
            continue
        # result type region: between " = " and the op name (handles tuple
        # results of async -start variants)
        region = line[eq + 3:mm.start()]
        size = sum(_shape_bytes(s) for s in _SHAPE_RE.finditer(region))
        if kind in ("all-gather", "all-reduce") and mm.group(2):
            # async start ops carry (input, output) tuples — count output only
            size = size // 2
        factor = 2 if kind == "all-reduce" else 1
        out[kind] += size * factor
        out["count"] += 1
    out["total"] = sum(v for k, v in out.items() if k not in ("count", "total"))
    return out


def cost_summary(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per computation
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def mem_summary(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "code_bytes": int(ma.generated_code_size_in_bytes),
    }


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------
def _axes_to_shardings(mesh, axes_tree, shapes_tree, rules, fallbacks=None):
    return sharding_tree(mesh, axes_tree, shapes_tree, rules, fallbacks)


def lower_full(cfg: ModelConfig, shape_name: str, mesh, rules: str):
    """Lower + compile the full step function for the cell.  Returns
    (compiled, lowered, fallbacks)."""
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape_name)
    fallbacks: list = []
    params = M.abstract_params(cfg)
    p_shard = _axes_to_shardings(mesh, M.param_axes(cfg), params, rules, fallbacks)
    b_shard = batch_sharding(mesh, specs, rules)
    ocfg = AdamWConfig()

    if shape.kind == "train":
        opt = abstract_opt_state(params)
        o_shard = {"m": p_shard, "v": p_shard, "step": replicated(mesh)}

        def train_step(p, o, b):
            loss, grads = jax.value_and_grad(lambda pp: M.loss_fn(pp, b, cfg))(p)
            new_p, new_o, metrics = adamw_update(grads, p, o, ocfg)
            return loss, new_p, new_o

        jitted = jax.jit(
            train_step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(replicated(mesh), p_shard, o_shard),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params, opt, specs)
    elif shape.kind == "prefill":
        def prefill_step(p, b):
            return M.prefill(p, b, cfg, max_len=shape.seq_len)

        cache_shapes = jax.eval_shape(
            lambda: M.make_cache(cfg, shape.global_batch, shape.seq_len))
        c_shard = _axes_to_shardings(mesh, M.cache_axes(cfg), cache_shapes,
                                     rules, fallbacks)
        logits_shard = NamedSharding(
            mesh, spec_for(mesh, ("batch", None, None),
                           (shape.global_batch, 1, cfg.vocab), RULE_SETS[rules]))
        jitted = jax.jit(prefill_step, in_shardings=(p_shard, b_shard),
                         out_shardings=(c_shard, logits_shard))
        lowered = jitted.lower(params, specs)
    else:  # decode
        cache_shapes = jax.eval_shape(
            lambda: M.make_cache(cfg, shape.global_batch, shape.seq_len))
        c_shard = _axes_to_shardings(mesh, M.cache_axes(cfg), cache_shapes,
                                     rules, fallbacks)
        logits_shard = NamedSharding(
            mesh, spec_for(mesh, ("batch", None, None),
                           (shape.global_batch, 1, cfg.vocab), RULE_SETS[rules]))

        def serve_step(p, c, b):
            return M.decode_step(p, c, b, cfg)

        jitted = jax.jit(serve_step,
                         in_shardings=(p_shard, c_shard, b_shard),
                         out_shardings=(logits_shard, c_shard),
                         donate_argnums=(1,))
        lowered = jitted.lower(params, cache_shapes, specs)
    t0 = time.time()
    compiled = lowered.compile()
    return compiled, lowered, fallbacks, time.time() - t0


# ---------------------------------------------------------------------------
# Per-layer accounting (FLOP-exact decomposition)
# ---------------------------------------------------------------------------
def _layer_abstract(cfg: ModelConfig):
    """One layer's abstract params + axes (no leading 'layers' dim)."""
    specs = M.layer_specs(cfg)
    shapes = M._nest({k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                      for k, v in specs.items()})
    axes = M._nest({k: v.axes for k, v in specs.items()})
    return shapes, axes


def _shared_abstract(cfg: ModelConfig):
    specs = {k[len("shared/"):]: v for k, v in M.model_specs(cfg).items()
             if k.startswith("shared/")}
    if not specs:
        return None, None
    shapes = M._nest({k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                      for k, v in specs.items()})
    axes = M._nest({k: v.axes for k, v in specs.items()})
    return shapes, axes


def _acct(lowered) -> dict:
    compiled = lowered.compile()
    c = cost_summary(compiled)
    c["collectives"] = collective_bytes(compiled.as_text())
    return c


def account_cell(cfg: ModelConfig, shape_name: str, mesh, rules: str,
                 flash: bool = False) -> dict:
    """Exact-FLOP decomposition: outer + n_layers x layer (+ zamba shared).
    ``flash``: lower attention as a kernel stub and add the Pallas kernel's
    analytic costs (EXPERIMENTS.md §Perf H3)."""
    shape = SHAPES[shape_name]
    if flash:
        cfg = cfg.replace(attn_impl="kernel_stub")
    B, S = shape.global_batch, shape.seq_len
    rule = RULE_SETS[rules]
    out: dict = {"n_layers": cfg.n_layers}

    lp_shapes, lp_axes = _layer_abstract(cfg)
    lp_shard = _axes_to_shardings(mesh, lp_axes, lp_shapes, rules)
    x_sds = jax.ShapeDtypeStruct((B, S if shape.kind != "decode" else 1,
                                  cfg.d_model), jnp.bfloat16)
    x_shard = NamedSharding(mesh, spec_for(mesh, ("batch", None, None),
                                           x_sds.shape, rule))
    if cfg.mrope:
        pos_sds = jax.ShapeDtypeStruct((B, x_sds.shape[1], 3), jnp.int32)
    else:
        pos_sds = jax.ShapeDtypeStruct((B, x_sds.shape[1]), jnp.int32)
    pos_shard = NamedSharding(mesh, spec_for(mesh, ("batch",) + (None,) * (len(pos_sds.shape) - 1),
                                             pos_sds.shape, rule))

    # RWKV's time scan is unrolled at a reduced S and scaled (all ops linear)
    s_acc, scale = (S, 1.0)
    if cfg.rwkv and shape.kind != "decode":
        s_acc = min(S, 256)
        scale = S / s_acc
        x_sds = jax.ShapeDtypeStruct((B, s_acc, cfg.d_model), jnp.bfloat16)
        pos_sds = jax.ShapeDtypeStruct((B, s_acc), jnp.int32)

    if shape.kind in ("train", "prefill"):
        def layer_fwd(lp, x, pos):
            y, aux = M.layer_step(lp, x, pos, jnp.int32(0), cfg, unroll=True)
            return y

        if shape.kind == "train":
            def layer_train(lp, x, pos):
                f = layer_fwd
                if cfg.remat == "block":
                    f = jax.checkpoint(f)
                y = f(lp, x, pos)
                # bf16 sum: the real inter-layer cotangent is the bf16
                # residual stream, so grads/collectives stay bf16-sized
                return jnp.sum(y)

            g = jax.value_and_grad(layer_train, argnums=(0, 1))
            low = jax.jit(g, in_shardings=(lp_shard, x_shard, pos_shard)
                          ).lower(lp_shapes, x_sds, pos_sds)
        else:
            low = jax.jit(layer_fwd, in_shardings=(lp_shard, x_shard, pos_shard)
                          ).lower(lp_shapes, x_sds, pos_sds)
        out["layer"] = _acct(low)
        out["layer_scale"] = scale

        # zamba2: the shared attention(+MLP) block runs n_shared times and is
        # NOT inside the per-layer cost (layer_step's cond skips it when
        # shared=None) — account it separately at full S (it is quadratic).
        if cfg.attn_every:
            sh_shapes, sh_axes = _shared_abstract(cfg)
            sh_shard = _axes_to_shardings(mesh, sh_axes, sh_shapes, rules)
            x_full = jax.ShapeDtypeStruct((B, S if shape.kind != "decode" else 1,
                                           cfg.d_model), jnp.bfloat16)
            xf_shard = NamedSharding(mesh, spec_for(mesh, ("batch", None, None),
                                                    x_full.shape, rule))
            pos_full = jax.ShapeDtypeStruct((B, x_full.shape[1]), jnp.int32)
            pf_shard = NamedSharding(mesh, spec_for(mesh, ("batch", None),
                                                    pos_full.shape, rule))

            def shared_fwd(sp, x, pos):
                from repro.models.layers import (attention_block, mlp_block,
                                                 rmsnorm)
                cat = jnp.concatenate([x, x], axis=-1)
                h = rmsnorm(cat, sp["ln_in"]["scale"], cfg.norm_eps)
                a = attention_block(h, sp["attn"], cfg, pos, unroll=True)
                xx = x + a
                h2 = rmsnorm(xx, sp["ln_mlp"]["scale"], cfg.norm_eps)
                return xx + mlp_block(h2, sp["mlp"], cfg)

            if shape.kind == "train":
                gsh = jax.value_and_grad(
                    lambda sp, x, pos: jnp.sum(shared_fwd(sp, x, pos)),
                    argnums=(0, 1))
                low = jax.jit(gsh, in_shardings=(sh_shard, xf_shard, pf_shard)
                              ).lower(sh_shapes, x_full, pos_full)
            else:
                low = jax.jit(shared_fwd, in_shardings=(sh_shard, xf_shard, pf_shard)
                              ).lower(sh_shapes, x_full, pos_full)
            out["shared"] = _acct(low)
            out["n_shared"] = cfg.n_shared_attn

        # outer: embedding + head + loss (train) / head only (prefill)
        specs = input_specs(cfg, shape_name)
        b_shard = batch_sharding(mesh, specs, rules)
        pe = jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), jnp.bfloat16)
        ph = jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab), jnp.bfloat16)
        pn = jax.ShapeDtypeStruct((cfg.d_model,), jnp.bfloat16)
        pe_sh = NamedSharding(mesh, spec_for(mesh, ("vocab", "embed"), pe.shape, rule))
        ph_sh = NamedSharding(mesh, spec_for(mesh, ("embed", "vocab"), ph.shape, rule))
        pn_sh = replicated(mesh)

        def outer_fn(pe_, ph_, pn_, b):
            prm = {"embed": {"table": pe_}, "final_norm": {"scale": pn_},
                   "lm_head": {"w": ph_}}
            x, _ = M._embed_inputs(prm, b, cfg)
            logits = M._logits(prm, x, cfg)
            if shape.kind == "train":
                targets = b["targets"]
                mask = (targets >= 0).astype(jnp.float32)
                t = jnp.clip(targets, 0)
                logz = jax.nn.logsumexp(logits, axis=-1)
                gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
                return ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
            return jnp.sum(logits[:, -1].astype(jnp.float32))

        if shape.kind == "train":
            gout = jax.value_and_grad(outer_fn, argnums=(0, 1, 2))
            low = jax.jit(gout, in_shardings=(pe_sh, ph_sh, pn_sh, b_shard)
                          ).lower(pe, ph, pn, specs)
        else:
            low = jax.jit(outer_fn, in_shardings=(pe_sh, ph_sh, pn_sh, b_shard)
                          ).lower(pe, ph, pn, specs)
        out["outer"] = _acct(low)

        # AdamW update flops (train): elementwise over params — analytic
        if shape.kind == "train":
            out["optimizer_flops_analytic"] = 14.0 * M.n_params(cfg) / mesh.size
        if flash:
            out["flash_kernel"] = flash_kernel_costs(cfg, shape_name, mesh.size)
        return out

    # ---- decode accounting ----
    cache_shapes = jax.eval_shape(lambda: M.make_cache(cfg, B, S))
    c_axes = M.cache_axes(cfg)
    length = jax.ShapeDtypeStruct((), jnp.int32)

    if cfg.rwkv:
        def dec_layer(lp, x, wkv, tm, cm):
            lc = {"wkv": wkv, "tm_x": tm, "cm_x": cm}
            y, nc = M.decode_layer_step(lp, x, cfg, lc, jnp.int32(0),
                                        jnp.zeros((B, 1), jnp.int32), jnp.int32(0))
            return y, nc

        wkv = jax.ShapeDtypeStruct(cache_shapes["wkv"].shape[1:], jnp.float32)
        tm = jax.ShapeDtypeStruct(cache_shapes["tm_x"].shape[1:], jnp.bfloat16)
        cm = jax.ShapeDtypeStruct(cache_shapes["cm_x"].shape[1:], jnp.bfloat16)
        shard_of = lambda ax, sds: NamedSharding(mesh, spec_for(mesh, ax, sds.shape, rule))
        low = jax.jit(dec_layer, in_shardings=(
            lp_shard, x_shard,
            shard_of(("batch", "ssm_heads", None, None), wkv),
            shard_of(("batch", None, None), tm),
            shard_of(("batch", None, None), cm)),
            donate_argnums=(2, 3, 4),
        ).lower(lp_shapes, x_sds, wkv, tm, cm)
        out["layer"] = _acct(low)
        out["layer_scale"] = 1.0
    elif cfg.family == "hybrid":
        from repro.models.ssm import mamba2_decode_step

        def dec_layer(lp, x, ssm, conv):
            h = x  # norm negligible
            return mamba2_decode_step(h, lp["mamba"], cfg, ssm, conv)

        ssm = jax.ShapeDtypeStruct(cache_shapes["ssm"].shape[1:], jnp.float32)
        conv = jax.ShapeDtypeStruct(cache_shapes["conv"].shape[1:], jnp.bfloat16)
        shard_of = lambda ax, sds: NamedSharding(mesh, spec_for(mesh, ax, sds.shape, rule))
        low = jax.jit(dec_layer, in_shardings=(
            lp_shard, x_shard,
            shard_of(("batch", "ssm_heads", None, None), ssm),
            shard_of(("batch", None, None), conv)),
            donate_argnums=(2, 3),
        ).lower(lp_shapes, x_sds, ssm, conv)
        out["layer"] = _acct(low)
        out["layer_scale"] = 1.0

        # shared attention decode over the full cache
        sh_shapes, sh_axes = _shared_abstract(cfg)
        sh_shard = _axes_to_shardings(mesh, sh_axes, sh_shapes, rules)
        kc = jax.ShapeDtypeStruct(cache_shapes["k"].shape[1:], jnp.bfloat16)
        vc = jax.ShapeDtypeStruct(cache_shapes["v"].shape[1:], jnp.bfloat16)
        kc_sh = shard_of(("batch", None, "kv_cache_heads", None), kc)

        def dec_shared(sp, x, k, v):
            from repro.models.layers import (attention_decode_block, mlp_block,
                                             rmsnorm)
            cat = jnp.concatenate([x, x], axis=-1)
            h = rmsnorm(cat, sp["ln_in"]["scale"], cfg.norm_eps)
            a, k, v = attention_decode_block(h, sp["attn"], cfg,
                                             jnp.zeros((B, 1), jnp.int32), k, v,
                                             jnp.int32(S - 1))
            xx = x + a
            h2 = rmsnorm(xx, sp["ln_mlp"]["scale"], cfg.norm_eps)
            return xx + mlp_block(h2, sp["mlp"], cfg), k, v

        low = jax.jit(dec_shared, in_shardings=(sh_shard, x_shard, kc_sh, kc_sh),
                      donate_argnums=(2, 3)).lower(sh_shapes, x_sds, kc, vc)
        out["shared"] = _acct(low)
        out["n_shared"] = cfg.n_shared_attn
    else:
        def dec_layer(lp, x, *cache_leaves):
            keys = ["k", "v"] + (["k_scale", "v_scale"] if cfg.kv_quant else [])
            lc = dict(zip(keys, cache_leaves))
            if cfg.mrope:
                pos = jnp.full((B, 1, 3), S - 1, jnp.int32)
            else:
                pos = jnp.full((B, 1), S - 1, jnp.int32)
            y, nc = M.decode_layer_step(lp, x, cfg, lc, jnp.int32(S - 1),
                                        pos, jnp.int32(0))
            return y, nc

        kc = jax.ShapeDtypeStruct(cache_shapes["k"].shape[1:],
                                  cache_shapes["k"].dtype)
        kc_sh = NamedSharding(mesh, spec_for(
            mesh, ("batch", None, "kv_cache_heads", None), kc.shape, rule))
        leaves = [kc, kc]
        shards = [kc_sh, kc_sh]
        if cfg.kv_quant:
            sc = jax.ShapeDtypeStruct(cache_shapes["k_scale"].shape[1:],
                                      jnp.bfloat16)
            sc_sh = NamedSharding(mesh, spec_for(
                mesh, ("batch", None, "kv_cache_heads"), sc.shape, rule))
            leaves += [sc, sc]
            shards += [sc_sh, sc_sh]
        low = jax.jit(dec_layer, in_shardings=tuple([lp_shard, x_shard] + shards),
                      donate_argnums=tuple(range(2, 2 + len(leaves)))
                      ).lower(lp_shapes, x_sds, *leaves)
        out["layer"] = _acct(low)
        out["layer_scale"] = 1.0

    # outer decode: embed row + head matmul
    pe = jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), jnp.bfloat16)
    ph = jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab), jnp.bfloat16)
    pn = jax.ShapeDtypeStruct((cfg.d_model,), jnp.bfloat16)
    pe_sh = NamedSharding(mesh, spec_for(mesh, ("vocab", "embed"), pe.shape, rule))
    ph_sh = NamedSharding(mesh, spec_for(mesh, ("embed", "vocab"), ph.shape, rule))
    toks = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tk_sh = NamedSharding(mesh, spec_for(mesh, ("batch", None), toks.shape, rule))

    def outer_dec(pe_, ph_, pn_, t):
        prm = {"embed": {"table": pe_}, "final_norm": {"scale": pn_},
               "lm_head": {"w": ph_}}
        x = pe_[t]
        return M._logits(prm, x, cfg)

    low = jax.jit(outer_dec, in_shardings=(pe_sh, ph_sh, replicated(mesh), tk_sh)
                  ).lower(pe, ph, pn, toks)
    out["outer"] = _acct(low)
    return out


# ---------------------------------------------------------------------------
# Analytic reference (MODEL_FLOPS)
# ---------------------------------------------------------------------------
def flash_kernel_costs(cfg: ModelConfig, shape_name: str, n_dev: int) -> dict:
    """Analytic per-device cost of the Pallas flash-attention kernel for one
    step: FLOPs = 2 matmuls over the causal triangle (x3.5 for train: fwd +
    bwd incl. recompute); HBM bytes = q/k/v read + o written (x2.5 train).
    Scores/probabilities live in VMEM (that is the point of the kernel)."""
    shape = SHAPES[shape_name]
    if shape.kind == "decode" or cfg.n_heads == 0:
        return {"flops": 0.0, "bytes": 0.0}
    S, B = shape.seq_len, shape.global_batch
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    n_attn = cfg.n_shared_attn if cfg.family == "hybrid" else cfg.n_layers
    flops = 2 * 2 * B * H * hd * (S * S / 2.0)          # QK^T + PV, causal
    bytes_ = 2 * B * S * hd * (2 * H + 2 * K)           # q,o (H) + k,v (K) bf16
    mult_f = 3.5 if shape.kind == "train" else 1.0
    mult_b = 2.5 if shape.kind == "train" else 1.0
    return {"flops": flops * n_attn * mult_f / n_dev,
            "bytes": bytes_ * n_attn * mult_b / n_dev}


def attn_score_hbm_bytes(cfg: ModelConfig, shape_name: str, n_dev: int) -> float:
    """Per-device HBM bytes the jnp chunked-attention stand-in spends on the
    (cq x ck) score/probability blocks per step.  The Pallas flash kernel
    (kernels/flash_attention) keeps these in VMEM, so the TPU deployment's
    memory term subtracts them (documented in EXPERIMENTS.md §Perf).
    Counted as ~3 f32 traversals (scores out, exp in/out) of the triangular
    S^2/2 block area per layer, q-heads wide."""
    shape = SHAPES[shape_name]
    if shape.kind == "decode" or cfg.n_heads == 0:
        return 0.0
    S, B = shape.seq_len, shape.global_batch
    per_layer = 3.0 * 4.0 * B * cfg.n_heads * (S * S / 2.0)
    n_attn_layers = cfg.n_shared_attn if cfg.family == "hybrid" else cfg.n_layers
    mult = 3.0 if shape.kind == "train" else 1.0   # fwd + bwd recompute
    return per_layer * n_attn_layers * mult / n_dev


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) for train; 2*N*D for inference
    fwd; decode D = batch tokens (1 per seq)."""
    shape = SHAPES[shape_name]
    n_active = M.n_active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, multi_pod: bool, rules: str,
             out_dir: Path, skip_accounting: bool = False,
             kv_quant: bool = False, flash: bool = False,
             moe_a2a: bool = False) -> dict:
    cfg = get(arch)
    if kv_quant:
        cfg = cfg.replace(kv_quant=True)
    if moe_a2a:
        cfg = cfg.replace(moe_impl="a2a")
    if SHAPES[shape_name].seq_len >= 32768 and not cfg.rwkv:
        # larger chunks at long S keep the unrolled accounting HLO small
        cfg = cfg.replace(attn_chunk_q=2048, attn_chunk_k=2048)
    skip = shape_applicable(cfg, shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "rules": rules, "ts": time.time()}
    if skip:
        rec["status"] = skip
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_current_mesh(mesh, rules)   # model-level sharding constraints (MoE EP)
    t0 = time.time()
    compiled, lowered, fallbacks, compile_s = lower_full(cfg, shape_name, mesh, rules)
    rec.update(
        status="ok",
        n_devices=mesh.size,
        compile_seconds=compile_s,
        lower_seconds=time.time() - t0 - compile_s,
        memory=mem_summary(compiled),
        full_cost=cost_summary(compiled),
        full_collectives=collective_bytes(compiled.as_text()),
        sharding_fallbacks=[f"{n}:dim{d}%{e}" for n, s, d, e in fallbacks],
        model_flops=model_flops(cfg, shape_name),
        attn_score_hbm_bytes=attn_score_hbm_bytes(cfg, shape_name, mesh.size),
        n_params=M.n_params(cfg),
        n_active_params=M.n_active_params(cfg),
    )
    if not skip_accounting and not multi_pod:
        rec["accounting"] = account_cell(cfg, shape_name, mesh, rules,
                                         flash=flash)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default="fsdp_tp")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-accounting", action="store_true")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--flash", action="store_true")
    ap.add_argument("--moe-a2a", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = [args.arch] if args.arch else [a for a in ARCHS if a != "paper-scorer"]
    shapes = [args.shape] if args.shape else list(SHAPES)
    for arch in archs:
        for shape in shapes:
            tag = (f"{arch}__{shape}__"
                   f"{'pod2x16x16' if args.multi_pod else 'pod16x16'}__"
                   f"{args.rules}{args.tag}")
            path = out_dir / f"{tag}.json"
            if path.exists():
                print(f"[skip cached] {tag}")
                continue
            t0 = time.time()
            try:
                rec = run_cell(arch, shape, args.multi_pod, args.rules, out_dir,
                               args.skip_accounting, kv_quant=args.kv_quant,
                               flash=args.flash, moe_a2a=args.moe_a2a)
            except Exception as e:  # noqa: BLE001 — record the failure
                import traceback
                rec = {"arch": arch, "shape": shape, "rules": args.rules,
                       "mesh": "pod2x16x16" if args.multi_pod else "pod16x16",
                       "status": f"FAILED: {type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
            rec["wall_seconds"] = time.time() - t0
            path.write_text(json.dumps(rec, indent=1))
            print(f"[{rec.get('status', '?')[:60]:60s}] {tag} ({rec['wall_seconds']:.0f}s)")


if __name__ == "__main__":
    main()
